"""Paged KV cache: allocation/lifetime invariants + attention equivalence."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

import jax.numpy as jnp

from repro.serving.paged_cache import OutOfBlocksError, PagedKVCache, \
    paged_decode_attention


def _cache(blocks=8, bs=4, layers=2, hkv=2, d=8):
    return PagedKVCache(num_layers=layers, num_blocks=blocks, block_size=bs,
                        num_kv_heads=hkv, head_dim=d)


def test_allocation_and_release_roundtrip():
    c = _cache()
    c.allocate(1, tokens=10)            # ceil(10/4) = 3 blocks
    assert len(c.blocks_for(1)) == 3
    assert c.free_blocks() == 5
    assert c.release(1) == 3
    assert c.free_blocks() == 8
    assert c.blocks_for(1) == []


def test_pool_exhaustion_raises():
    c = _cache(blocks=2, bs=4)
    c.allocate(1, tokens=8)
    c.allocate(2)
    with pytest.raises(OutOfBlocksError):
        c._grow(2, 1)


def test_append_gather_matches_contiguous(rng):
    c = _cache(blocks=16, bs=4, layers=3, hkv=2, d=8)
    c.allocate(7)
    ref_k, ref_v = [], []
    for t in range(11):                  # crosses block boundaries
        lk = rng.randn(3, 2, 8).astype(np.float32)
        lv = rng.randn(3, 2, 8).astype(np.float32)
        c.append(7, jnp.asarray(lk), jnp.asarray(lv))
        ref_k.append(lk)
        ref_v.append(lv)
    for layer in range(3):
        k, v = c.gather(7, layer)
        np.testing.assert_allclose(
            np.asarray(k), np.stack([r[layer] for r in ref_k]), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(v), np.stack([r[layer] for r in ref_v]), atol=1e-6)


def test_paged_attention_matches_dense(rng):
    c = _cache(blocks=16, bs=4, layers=1, hkv=2, d=8)
    c.allocate(0)
    ks, vs = [], []
    for _ in range(9):
        lk = rng.randn(1, 2, 8).astype(np.float32)
        lv = rng.randn(1, 2, 8).astype(np.float32)
        c.append(0, jnp.asarray(lk), jnp.asarray(lv))
        ks.append(lk[0])
        vs.append(lv[0])
    q = jnp.asarray(rng.randn(4, 8), jnp.float32)       # H=4, G=2
    o = paged_decode_attention(c, 0, 0, q)
    # dense reference
    K = np.stack(ks)
    V = np.stack(vs)
    qg = np.asarray(q).reshape(2, 2, 8)
    s = np.einsum("hgd,nhd->hgn", qg, K) / np.sqrt(8)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    ref = np.einsum("hgn,nhd->hgd", p, V).reshape(4, 8)
    np.testing.assert_allclose(np.asarray(o), ref, atol=1e-5)


def test_gather_empty_respects_pool_dtype():
    """Regression: the zero-length gather returned hard-coded float32
    empties — downstream concatenation silently upcast bf16/f16 pools."""
    c = PagedKVCache(num_layers=1, num_blocks=4, block_size=4,
                     num_kv_heads=2, head_dim=8, dtype="bfloat16")
    c.allocate(0)
    k, v = c.gather(0, 0)
    assert k.shape == (0, 2, 8) and v.shape == (0, 2, 8)
    assert k.dtype == jnp.bfloat16 and v.dtype == jnp.bfloat16


def test_zero_length_attention_is_defined_error(rng):
    """Regression: attention over zero stored tokens softmaxed an empty
    axis into NaNs; it must be a ValueError, not NaN propagation."""
    c = _cache(blocks=4, bs=4, layers=1)
    c.allocate(0)
    q = jnp.asarray(rng.randn(4, 8), jnp.float32)
    with pytest.raises(ValueError, match="zero-length"):
        paged_decode_attention(c, 0, 0, q)
    # unallocated sequence ids fail the same way (no KeyError leak)
    with pytest.raises(ValueError, match="zero-length"):
        paged_decode_attention(c, 99, 0, q)


def test_null_block_is_reserved_and_pads_tables():
    """The null row sits past the allocatable range (accounting is
    unchanged) and pads both axes of device table arrays."""
    c = _cache(blocks=8, bs=4)
    assert c.null_block == 8
    assert c.k.shape[1] == 9                 # num_blocks + 1 physical rows
    assert c.free_blocks() == 8              # null row never allocatable
    c.allocate(1, tokens=6)                  # 2 blocks
    t = c.table_array([1, 2], width=4, rows=3)
    assert t.shape == (3, 4) and t.dtype == np.int32
    assert list(t[0][:2]) == c.blocks_for(1)
    assert (t[0][2:] == c.null_block).all()  # width padding
    assert (t[1] == c.null_block).all()      # unallocated seq -> all null
    assert (t[2] == c.null_block).all()      # rows padding
    assert list(c.lengths_array([1, 2], rows=3)) == [0, 0, 0]


def test_failed_reservation_rolls_back():
    """An allocate() that exhausts the pool mid-reservation must not leak
    a half-grown table."""
    c = _cache(blocks=3, bs=4)
    c.allocate(1, tokens=8)                  # 2 blocks
    with pytest.raises(OutOfBlocksError):
        c.allocate(2, tokens=12)             # needs 3, only 1 free
    assert 2 not in c.tables and 2 not in c.lengths
    assert c.free_blocks() == 1              # the partial grow rolled back


def test_engine_exhaustion_lifecycle_chaos(rng):
    """ISSUE 8 satellite: fill the pool through the engine, observe shed
    verdicts (never OutOfBlocksError), release on completion, and verify
    freed blocks are reused with no leaked table entries across
    chaos-style random admit/release rounds."""
    import jax
    from repro.configs import get_config
    from repro.models import transformer as tf_mod
    from repro.models.common import init_params
    from repro.serving.engine import Request
    from repro.serving.paged_engine import PagedServingEngine
    from repro.serving.scheduler import DeadlineScheduler

    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf_mod.model_specs(cfg))
    eng = PagedServingEngine(cfg, params, max_batch=2, max_seq=32,
                             block_size=4, num_blocks=6,
                             scheduler=DeadlineScheduler())
    total = eng.cache.num_blocks
    served = shed = 0
    rid = 0
    for round_ in range(4):
        reqs = []
        for _ in range(int(rng.randint(1, 5))):
            plen = int(rng.randint(2, 9))
            reqs.append(Request(
                rid=rid, prompt=rng.randint(0, cfg.vocab_size, (plen,))
                .astype(np.int32), max_new=int(rng.randint(1, 7))))
            rid += 1
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        for r in reqs:
            assert r.done
            if r.shed:
                shed += 1
                assert "out of KV blocks" in r.verdict
                assert r.out_tokens == []        # zero compute spent
            else:
                served += 1
                assert len(r.out_tokens) == r.max_new + 1
        # drained => every block released, no leaked table entries
        assert eng.cache.tables == {} and eng.cache.lengths == {}
        assert eng.cache.free_blocks() == total
    assert served > 0        # freed blocks were reused across rounds


if _HAS_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 9)),
                    min_size=1, max_size=24))
    @settings(max_examples=40, deadline=None)
    def test_property_no_block_leaks_or_double_use(ops):
        """Interleaved allocate/grow/release never leaks or double-books a
        physical block."""
        c = _cache(blocks=12, bs=2)
        for seq, tokens in ops:
            try:
                if seq in c.tables:
                    c.release(seq)
                else:
                    c.allocate(seq, tokens=tokens)
            except OutOfBlocksError:
                pass
            # invariants
            held = [b for t in c.tables.values() for b in t]
            assert len(held) == len(set(held))              # no double-booking
            assert len(held) + c.free_blocks() == 12        # no leaks
            assert set(held).isdisjoint(c._free)
