"""Paged KV cache: allocation/lifetime invariants + attention equivalence."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

import jax.numpy as jnp

from repro.serving.paged_cache import OutOfBlocksError, PagedKVCache, \
    paged_decode_attention


def _cache(blocks=8, bs=4, layers=2, hkv=2, d=8):
    return PagedKVCache(num_layers=layers, num_blocks=blocks, block_size=bs,
                        num_kv_heads=hkv, head_dim=d)


def test_allocation_and_release_roundtrip():
    c = _cache()
    c.allocate(1, tokens=10)            # ceil(10/4) = 3 blocks
    assert len(c.blocks_for(1)) == 3
    assert c.free_blocks() == 5
    assert c.release(1) == 3
    assert c.free_blocks() == 8
    assert c.blocks_for(1) == []


def test_pool_exhaustion_raises():
    c = _cache(blocks=2, bs=4)
    c.allocate(1, tokens=8)
    c.allocate(2)
    with pytest.raises(OutOfBlocksError):
        c._grow(2, 1)


def test_append_gather_matches_contiguous(rng):
    c = _cache(blocks=16, bs=4, layers=3, hkv=2, d=8)
    c.allocate(7)
    ref_k, ref_v = [], []
    for t in range(11):                  # crosses block boundaries
        lk = rng.randn(3, 2, 8).astype(np.float32)
        lv = rng.randn(3, 2, 8).astype(np.float32)
        c.append(7, jnp.asarray(lk), jnp.asarray(lv))
        ref_k.append(lk)
        ref_v.append(lv)
    for layer in range(3):
        k, v = c.gather(7, layer)
        np.testing.assert_allclose(
            np.asarray(k), np.stack([r[layer] for r in ref_k]), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(v), np.stack([r[layer] for r in ref_v]), atol=1e-6)


def test_paged_attention_matches_dense(rng):
    c = _cache(blocks=16, bs=4, layers=1, hkv=2, d=8)
    c.allocate(0)
    ks, vs = [], []
    for _ in range(9):
        lk = rng.randn(1, 2, 8).astype(np.float32)
        lv = rng.randn(1, 2, 8).astype(np.float32)
        c.append(0, jnp.asarray(lk), jnp.asarray(lv))
        ks.append(lk[0])
        vs.append(lv[0])
    q = jnp.asarray(rng.randn(4, 8), jnp.float32)       # H=4, G=2
    o = paged_decode_attention(c, 0, 0, q)
    # dense reference
    K = np.stack(ks)
    V = np.stack(vs)
    qg = np.asarray(q).reshape(2, 2, 8)
    s = np.einsum("hgd,nhd->hgn", qg, K) / np.sqrt(8)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    ref = np.einsum("hgn,nhd->hgd", p, V).reshape(4, 8)
    np.testing.assert_allclose(np.asarray(o), ref, atol=1e-5)


if _HAS_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 9)),
                    min_size=1, max_size=24))
    @settings(max_examples=40, deadline=None)
    def test_property_no_block_leaks_or_double_use(ops):
        """Interleaved allocate/grow/release never leaks or double-books a
        physical block."""
        c = _cache(blocks=12, bs=2)
        for seq, tokens in ops:
            try:
                if seq in c.tables:
                    c.release(seq)
                else:
                    c.allocate(seq, tokens=tokens)
            except OutOfBlocksError:
                pass
            # invariants
            held = [b for t in c.tables.values() for b in t]
            assert len(held) == len(set(held))              # no double-booking
            assert len(held) + c.free_blocks() == 12        # no leaks
            assert set(held).isdisjoint(c._free)
