"""Fleet controller: autoscaling decisions, live mesh reshape with zero
dropped requests, hot weight swap (probe / commit / rollback), RIMFS
residency under swap, client backpressure retry, and a chaos-harness
smoke run (ISSUE 6)."""
import threading
import time

import numpy as np
import pytest

from repro.core import rctc, rhal, rimfs
from repro.core.fleet import FleetConfig, FleetController, FleetError
from repro.serving.protocol import F_CANARY
from repro.serving.server import (Client, InferenceServer, ServerBusy,
                                  _Work)

DEPTH, N = 8, 24


@pytest.fixture(scope="module")
def chain_setup():
    prog = rctc.compile_gemm_chain(DEPTH, N)
    files = rctc.gemm_chain_weights(DEPTH, N)
    return prog, files, rimfs.pack(files)


def _start(prog, image, mesh_groups=2, **kw):
    mesh = rhal.TileMesh(mesh_groups) if mesh_groups else None
    server = InferenceServer(mesh=mesh, **kw)
    addr = server.start()
    client = Client(addr)
    client.provision(image, prog.encode())
    return server, addr, client


def _x(seed=0):
    return np.random.RandomState(seed).randn(N, N).astype(np.float32)


def _wedge_dispatcher(server):
    """Park the dispatcher on a gate via a control op (the deterministic
    stand-in for a drain window / long-running dispatch)."""
    gate = threading.Event()
    entered = threading.Event()

    def ctl():
        entered.set()
        gate.wait(30)

    assert server._loop.submit(_Work(frame=None, route=None, control=ctl))
    assert entered.wait(5)
    return gate


# ------------------------------------------------------------- scale cycle
def test_scale_cycle_bit_identical_and_cached_mesh(chain_setup):
    """2 -> 4 -> 8 -> 2 under pipelined traffic: every response
    bit-identical, scaling back reuses the cached original mesh and
    re-uploads zero weight bytes."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server)
        x = _x(1)
        ref = client.infer(input=x)

        def total_dma():
            return sum(g.driver.stats.get("dma_bytes", 0)
                       for g in server.mesh.groups)

        d0 = total_dma()
        client.infer(input=x)
        per_req = total_dma() - d0      # steady per-request movement

        for n_groups in (4, 8):
            rids = [client.infer_async(input=x) for _ in range(3)]
            rep = fleet.scale_to(n_groups)
            assert server.mesh.n_groups == n_groups
            assert rep["from"] != rep["to"] == n_groups
            for rid in rids:            # in-flight across the flip: all ok
                out = client.result(rid)
                for k in ref:
                    np.testing.assert_array_equal(ref[k], out[k])

        rep = fleet.scale_to(2)
        assert rep["cached_mesh"], "original 2-mesh should be cache-hit"
        d2 = total_dma()
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        # back on the original drivers: the request cost its steady
        # per-request bytes, not a weight re-upload
        assert total_dma() - d2 == per_req
        kinds = [k for k, _ in fleet.events]
        assert kinds.count("scale_complete") == 3
    finally:
        client.close()
        server.stop()


def test_autoscaler_decides_up_on_real_backlog(chain_setup):
    """Queue depth from a wedged dispatcher drives the observe->decide
    loop up the ladder after the hysteresis streak; the backlog then
    drains without a single dropped request."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server, FleetConfig(scale_up_depth=6,
                                                    scale_up_ticks=2))
        x = _x(2)
        ref = client.infer(input=x)
        gate = _wedge_dispatcher(server)
        try:
            rids = [client.infer_async(input=x) for _ in range(8)]
            deadline = time.monotonic() + 5     # enqueue is async: wait
            while server.scheduler.pending() < 8:   # for the backlog to
                assert time.monotonic() < deadline  # actually land
                time.sleep(0.005)
            a1 = fleet.decide(fleet.observe())
            a2 = fleet.decide(fleet.observe())
            assert a1 is None                 # streak not yet reached
            assert a2 == ("scale", 4)         # second tick over threshold
        finally:
            gate.set()
        for rid in rids:
            out = client.result(rid)
            for k in ref:
                np.testing.assert_array_equal(ref[k], out[k])
        obs = fleet.observe()                 # drained: pressure gone
        assert fleet.decide(obs) is None and fleet._up_streak == 0
    finally:
        client.close()
        server.stop()


def test_single_dead_group_partial_reshape_zero_survivor_bytes(chain_setup):
    """One dead group in a multi-group mesh is spliced out by a partial
    reshape: the mesh OBJECT survives, only the replaced slot's driver
    changes, and the surviving groups' DMA counters move zero bytes
    during the repair (their residency is never touched)."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=4)
    try:
        fleet = FleetController(server)
        x = _x(3)
        ref = client.infer(input=x)
        mesh = server.mesh
        survivors = {g: mesh.group(g).driver for g in mesh.gids if g != 2}
        dma_before = {g: d.stats.get("dma_bytes", 0)
                      for g, d in survivors.items()}
        old_driver = mesh.group(2).driver
        mesh.kill(2)
        rep = fleet.tick()
        assert rep["action"] == ("replace", 2, "dead")
        assert "error" not in rep
        assert server.mesh is mesh              # same mesh, spliced slot
        assert mesh.group(2).driver is not old_driver
        for g, d in survivors.items():          # survivors untouched
            assert mesh.group(g).driver is d
            assert d.stats.get("dma_bytes", 0) == dma_before[g], \
                f"group {g} moved bytes during a partial reshape"
        assert all(mesh.alive(g) for g in mesh.gids)
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        kinds = [k for k, _ in fleet.events]
        assert "reshape_started" in kinds and "reshape_complete" in kinds
        assert "heal_complete" not in kinds
    finally:
        client.close()
        server.stop()


def test_multi_dead_groups_fall_back_to_full_heal(chain_setup):
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=4)
    try:
        fleet = FleetController(server)
        x = _x(3)
        ref = client.infer(input=x)
        doomed = server.mesh
        server.mesh.kill(1)
        server.mesh.kill(2)
        rep = fleet.tick()
        assert rep["action"] == ("heal", (1, 2))
        assert "error" not in rep
        assert server.mesh is not doomed
        assert all(server.mesh.alive(g) for g in server.mesh.gids)
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        kinds = [k for k, _ in fleet.events]
        assert "heal_started" in kinds and "heal_complete" in kinds
    finally:
        client.close()
        server.stop()


# ---------------------------------------------------------------- hot swap
def test_hot_swap_commits_and_stays_bit_identical(chain_setup):
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server)
        x = _x(4)
        ref = client.infer(input=x)
        old_bound = server._bound
        assert fleet.swap_weights(rimfs.pack(files),
                                  label="repack") == "committed"
        assert server._bound is not old_bound
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        kinds = [k for k, _ in fleet.events]
        assert kinds[-3:] == ["swap_started", "swap_probed",
                              "swap_committed"]
        # probation is REQUEST-count gated: serve enough traffic on the
        # new binding, then the tick floor finalizes it
        for i in range(fleet.cfg.probation_requests):
            client.infer(input=_x(40 + i))
        for _ in range(fleet.cfg.probation_ticks + 1):
            fleet.tick()
        assert not fleet.summary()["swap_in_probation"]
        assert "swap_finalized" in [k for k, _ in fleet.events]
    finally:
        client.close()
        server.stop()


def test_zero_traffic_probation_never_auto_commits(chain_setup):
    """Satellite regression: the swap probation window counts SERVED
    REQUESTS, not wall-clock ticks — an idle fleet can spin the control
    loop forever without the swap silently finalizing (the old image's
    residency stays pinned, so rollback remains a zero-byte flip)."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server)
        client.infer(input=_x(4))
        assert fleet.swap_weights(rimfs.pack(files),
                                  label="idle") == "committed"
        # many times the tick floor, zero traffic: still in probation
        for _ in range(fleet.cfg.probation_ticks * 5):
            rep = fleet.tick()
        assert rep["swap"]["state"] == "probation"
        assert rep["swap"]["served"] == 0
        assert fleet.summary()["swap_in_probation"]
        assert "swap_finalized" not in [k for k, _ in fleet.events]
        # rollback after the idle stretch is still possible and clean
        fleet.rollback(reason="test")
        assert not fleet.summary()["swap_in_probation"]
    finally:
        client.close()
        server.stop()


def test_bad_swap_detected_by_probe_and_rolled_back(chain_setup):
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server)
        x = _x(5)
        ref = client.infer(input=x)
        old_bound, old_fs = server._bound, server.platform.rimfs
        wrong = rctc.gemm_chain_weights(DEPTH, N, seed=123)
        assert fleet.swap_weights(rimfs.pack(wrong),
                                  label="wrong") == "rolled_back"
        # old binding still serving, bit-identically
        assert server._bound is old_bound
        assert server.platform.rimfs is old_fs
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        probed = [p for k, p in fleet.events if k == "swap_probed"]
        assert probed and probed[-1]["ok"] is False
        # a corrupt image never reaches the probe: mount refuses it
        broken = bytearray(rimfs.pack(files))
        broken[-2] ^= 0xFF
        assert fleet.swap_weights(bytes(broken),
                                  label="corrupt") == "rolled_back"
        reasons = [p["reason"] for k, p in fleet.events
                   if k == "swap_rolled_back"]
        assert any(r.startswith("mount:") for r in reasons)
    finally:
        client.close()
        server.stop()


def test_post_swap_miss_spike_triggers_auto_rollback(chain_setup):
    """A committed swap under probation rolls back automatically when
    the deadline-miss (shed) rate spikes; the old binding resumes with
    zero re-upload (its residency was never unpinned)."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server, FleetConfig(miss_spike=0.25,
                                                    spike_min_window=4))
        x = _x(6)
        ref = client.infer(input=x)
        old_bound = server._bound
        assert fleet.swap_weights(rimfs.pack(files),
                                  label="regressing") == "committed"
        server.scheduler.shed_count += 10      # simulated miss spike
        rep = fleet.tick()
        assert rep["swap"]["state"] == "rolled_back"
        assert server._bound is old_bound
        d0 = sum(g.driver.stats.get("dma_bytes", 0)
                 for g in server.mesh.groups)
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        # the post-rollback request moved activations only — the old
        # image's tile residency survived probation untouched, so the
        # weight bytes (len(image) scale) never re-uploaded
        moved = sum(g.driver.stats.get("dma_bytes", 0)
                    for g in server.mesh.groups) - d0
        assert moved < len(image) / 2
        reasons = [p["reason"] for k, p in fleet.events
                   if k == "swap_rolled_back"]
        assert any(r.startswith("miss_spike") for r in reasons)
    finally:
        client.close()
        server.stop()


# ------------------------------------------------------------------ canary
def test_canary_good_image_auto_promotes_bit_identical(chain_setup):
    """fraction=1.0 hash-routes every request through the shadow binding;
    identical weights agree on every SPRT sample, so the controller
    auto-promotes. Agreeing shadow-served replies carry F_CANARY, and
    promotion flips the binding atomically."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server)
        x = _x(8)
        ref = client.infer(input=x)
        old_bound = server._bound
        assert fleet.canary(rimfs.pack(files), fraction=1.0,
                            label="repack") == "started"
        assert server.canary is not None
        flagged = 0
        for _ in range(16):                 # > ~14 agrees the SPRT needs
            rid = client.infer_async(input=x)
            out, flags = client.result(rid, with_flags=True)
            for k in ref:
                np.testing.assert_array_equal(ref[k], out[k])
            if flags & F_CANARY:
                flagged += 1
        assert flagged == 16                # fraction 1.0: all shadow-served
        rep = fleet.tick()
        assert rep["canary"]["state"] == "promote"
        assert server.canary is None and fleet._canary is None
        assert server._bound is not old_bound
        promoted = [p for k, p in fleet.events if k == "canary_promoted"]
        assert promoted and promoted[-1]["disagrees"] == 0
        assert promoted[-1]["stats"]["served_shadow"] == 16
        out = client.infer(input=x)         # promoted binding serves on
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
    finally:
        client.close()
        server.stop()


def test_canary_bad_image_serves_zero_wrong_bytes_then_aborts(chain_setup):
    """A broken canary NEVER serves a byte it is known to have gotten
    wrong: every sampled request that disagrees is answered with the
    primary's bytes (no F_CANARY flag), and the SPRT aborts the rollout
    after min_samples. The primary binding is untouched throughout."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server)
        x = _x(9)
        ref = client.infer(input=x)
        old_bound, old_fs = server._bound, server.platform.rimfs
        wrong = rctc.gemm_chain_weights(DEPTH, N, seed=321)
        assert fleet.canary(rimfs.pack(wrong), fraction=1.0,
                            label="bad") == "started"
        for _ in range(6):
            rid = client.infer_async(input=x)
            out, flags = client.result(rid, with_flags=True)
            assert not (flags & F_CANARY)   # never the shadow's bytes
            for k in ref:                   # always the primary's answer
                np.testing.assert_array_equal(ref[k], out[k])
        rep = fleet.tick()
        assert rep["canary"]["state"] == "abort"
        assert server.canary is None and fleet._canary is None
        assert server._bound is old_bound
        assert server.platform.rimfs is old_fs
        aborted = [p for k, p in fleet.events if k == "canary_aborted"]
        assert aborted and aborted[-1]["reason"] == "sprt"
        assert aborted[-1]["stats"]["served_shadow"] == 0
        assert aborted[-1]["stats"]["disagree"] >= \
            fleet.cfg.canary_min_samples
        out = client.infer(input=x)         # primary serves on, untouched
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
    finally:
        client.close()
        server.stop()


def test_stage_ewma_straggler_replaced_in_place(chain_setup):
    """A group whose stage-busy EWMA sits far above its peer's median for
    straggler_ticks consecutive control-loop ticks is spliced out by a
    partial reshape — the fast peer's driver (and its pinned weights)
    are never touched."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        fleet = FleetController(server, FleetConfig(
            straggler_ticks=2, stage_straggler_ratio=2.0))
        x = _x(10)
        ref = client.infer(input=x)
        mesh = server.mesh
        old_slow = mesh.group(1).driver
        fast = mesh.group(0).driver
        # slot 1's stage-busy rhythm sits 25x above its peer's
        fleet._stage_ewma = {0: 0.01, 1: 0.25}
        r1 = fleet.tick()
        assert r1["action"] is None          # hysteresis: streak 1 of 2
        r2 = fleet.tick()
        assert r2["action"] == ("replace", 1, "straggler")
        assert "error" not in r2
        assert server.mesh is mesh           # same mesh, spliced slot
        assert mesh.group(1).driver is not old_slow
        assert mesh.group(0).driver is fast
        assert 1 not in fleet._stage_ewma    # fresh slot: rhythm reset
        out = client.infer(input=x)
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        started = [p for k, p in fleet.events if k == "reshape_started"]
        assert started and started[-1]["reason"] == "straggler"
        assert "reshape_complete" in [k for k, _ in fleet.events]
    finally:
        client.close()
        server.stop()


# ------------------------------------------------- RIMFS residency (swap)
def test_shadow_image_residency_no_evict_no_alias_zero_byte_rollback(rng):
    """Satellite: pinning a second weight image while the first is live
    must not evict, move or alias the first image's arena ranges; after
    rolling the shadow back, re-binding the original moves zero bytes."""
    drv = rhal.make_eager_driver()
    files_a = {f"w{i}": rng.randn(16, 16).astype(np.float32)
               for i in range(4)}
    files_b = {f"w{i}": rng.randn(16, 16).astype(np.float32)
               for i in range(4)}
    fs_a = rimfs.mount(rimfs.pack(files_a))
    fs_b = rimfs.mount(rimfs.pack(files_b))

    ra = fs_a.resident(drv)
    ranges_a = ra.pinned_ranges()
    live_a = {n: np.asarray(ra[n]) for n in ra.files()}

    rb = fs_b.resident(drv)                    # the shadow pin
    assert ra.pinned_ranges() == ranges_a      # nothing moved or evicted
    for o1, s1 in ranges_a:                    # no aliasing
        for o2, s2 in rb.pinned_ranges():
            assert o1 + s1 <= o2 or o2 + s2 <= o1
    for n in ra.files():                       # old bytes untouched
        np.testing.assert_array_equal(live_a[n], np.asarray(ra[n]))
        np.testing.assert_array_equal(live_a[n], files_a[n])

    rb.unpin()                                 # rollback: drop the shadow
    before = drv.stats.get("dma_bytes", 0)
    ra2 = fs_a.resident(drv)
    assert ra2 is ra                           # cache hit, same pinning
    assert drv.stats.get("dma_bytes", 0) == before   # zero bytes moved
    drv.arena.check()                          # raises on any violation


# ------------------------------------------------------------ client retry
def test_client_retry_drains_busy_burst(chain_setup):
    """Satellite regression: a burst into a wedged (drain-window-like)
    dispatcher hard-fails without retry, fully succeeds with bounded
    jittered-backoff retry enabled."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=0, max_queue=4)
    try:
        x = _x(7)
        ref = client.infer(input=x)

        # without retry: the overflow surfaces as ServerBusy
        gate = _wedge_dispatcher(server)
        try:
            plain = Client(addr)
            rids = [plain.infer_async(input=x) for _ in range(12)]
            outcomes = []
            for rid in rids:
                try:
                    outcomes.append(plain.result(rid))
                except ServerBusy:
                    outcomes.append("busy")
        finally:
            gate.set()
        assert "busy" in outcomes
        plain.close()

        # with retry: the same burst shape fully succeeds
        gate = _wedge_dispatcher(server)
        results, errors = [], []

        def worker(cid):
            cl = Client(addr, retries=20, backoff=0.01, retry_seed=cid)
            try:
                for _ in range(6):
                    results.append((cl.infer(input=x),
                                    cl.retry_stats["busy"]))
            except Exception as e:      # pragma: no cover
                errors.append(e)
            finally:
                cl.close()

        threads = [threading.Thread(target=worker, args=(c,))
                   for c in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.15)                # let the burst hit the wedge
        gate.set()
        for t in threads:
            t.join(timeout=60)
        assert not errors
        assert len(results) == 24
        for out, _ in results:
            for k in ref:
                np.testing.assert_array_equal(ref[k], out[k])
        assert any(busy > 0 for _, busy in results), \
            "burst never saw backpressure — wedge did not engage"
    finally:
        client.close()
        server.stop()


# ------------------------------------------------------------ chaos smoke
def test_chaos_smoke_converges():
    """A reduced chaos scenario (the CI chaos-matrix job runs the full
    one): zero failed requests, bit-identical outputs, all swap/heal
    events present."""
    import chaos
    report = chaos.run_chaos(groups=2, seed=3, requests=24, clients=2,
                             scale_peak=4, pace_s=0.01, dma_delay_s=0.1)
    assert chaos.check_report(report) == []
