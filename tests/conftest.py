"""Shared fixtures. NOTE: no XLA_FLAGS device forcing here — smoke tests and
benchmarks must see the real single CPU device; only launch/dryrun.py (and
explicit subprocess tests) force 512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
