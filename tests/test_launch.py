"""Launch-layer units: collective parser, roofline terms, input specs."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.launch.dryrun import parse_collectives
from repro.launch.roofline import TPU_HBM_BW, TPU_PEAK_FLOPS, analyze, \
    model_flops_per_device

HLO = """
HloModule test
%add (a: f32[], b: f32[]) -> f32[] { ... }
ENTRY %main {
  %p0 = f32[16,512]{1,0} parameter(0)
  %p1 = bf16[8,128]{1,0} parameter(1)
  %ar = f32[16,512]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%p1), dimensions={0}
  %ars = f32[16,512]{1,0} all-reduce-start(%p0), to_apply=%add
  %ard = f32[16,512]{1,0} all-reduce-done(%ars)
  %cp = bf16[8,128]{1,0} collective-permute(%p1), source_target_pairs={{0,1}}
}
"""


def test_parse_collectives_symbol_table():
    out = parse_collectives(HLO)
    assert out["bytes"]["all-reduce"] == 2 * 16 * 512 * 4   # ar + ar-start
    assert out["counts"]["all-reduce"] == 2                 # done not counted
    assert out["bytes"]["all-gather"] == 8 * 128 * 2        # operand bytes
    assert out["bytes"]["collective-permute"] == 8 * 128 * 2
    assert out["total_bytes"] == sum(out["bytes"].values())


def _rec(kind="train", flops=1e13, bts=1e12, coll=1e10, devices=256):
    return {
        "arch": "x", "shape": "s", "mesh": "pod256", "kind": kind,
        "devices": devices, "flops_per_device": flops,
        "bytes_per_device": bts, "collective_bytes_per_device": coll,
        "model": {"params": 1e9, "active_params": 1e9,
                  "global_batch": 256, "seq_len": 4096},
    }


def test_roofline_terms_and_dominance():
    r = analyze(_rec())
    assert r["compute_s"] == pytest.approx(1e13 / TPU_PEAK_FLOPS)
    assert r["memory_s"] == pytest.approx(1e12 / TPU_HBM_BW)
    assert r["dominant"] == "memory"
    assert 0 < r["roofline_fraction"] < 1


def test_model_flops_train_vs_decode():
    train = model_flops_per_device(_rec("train"))
    # 6*N*D/devices
    assert train == pytest.approx(6 * 1e9 * 256 * 4096 / 256)
    dec = model_flops_per_device(_rec("decode"))
    assert dec == pytest.approx(2 * 1e9 * 256 / 256)


def test_input_specs_shapes_every_cell():
    from repro.launch.steps import input_specs
    for arch in ("qwen3-14b", "rwkv6-1.6b", "pixtral-12b"):
        cfg = get_config(arch)
        for sname in applicable_shapes(cfg):
            sh = SHAPES[sname]
            spec = input_specs(cfg, sh)
            if sh.kind == "train":
                assert spec["targets"].shape == (sh.global_batch, sh.seq_len)
            if cfg.input_kind == "embeddings":
                assert spec["inputs"].shape[-1] == cfg.d_model
            if sh.kind == "decode":
                assert spec["inputs"].shape[int(
                    cfg.input_kind == "tokens")] == 1 or \
                    spec["inputs"].shape[1] == 1
                assert spec["pos"].shape == (sh.global_batch,)


def test_applicable_shapes_policy():
    assert "long_500k" in applicable_shapes(get_config("rwkv6-1.6b"))
    assert "long_500k" in applicable_shapes(get_config("hymba-1.5b"))
    assert "long_500k" not in applicable_shapes(get_config("qwen3-14b"))
    for a in ("qwen3-14b", "rwkv6-1.6b"):
        assert {"train_4k", "prefill_32k", "decode_32k"} <= \
            set(applicable_shapes(get_config(a)))
