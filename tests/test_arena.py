"""DeviceArena + residency plan: offset discipline, alignment, coalescing,
high-water accounting, and the linker's static transfer schedule."""
import numpy as np
import pytest

import jax

from repro.core import linker, rbl, rctc, rhal, rimfs
from repro.core.executor import Executor
from repro.core.rhal import ArenaError, DeviceArena

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Arena unit tests
# ---------------------------------------------------------------------------

def test_arena_alignment_and_high_water():
    a = DeviceArena(1 << 16, debug=True)
    o1 = a.alloc(1)                       # rounds up to one 128B lane
    o2 = a.alloc(129)                     # rounds up to 256
    assert o1 % 128 == 0 and o2 % 128 == 0
    assert a.bytes_in_use == 128 + 256
    assert a.high_water == 384
    a.free(o1)
    assert a.bytes_in_use == 256
    assert a.high_water == 384            # high-water is sticky


def test_arena_free_returns_range_and_coalesces():
    a = DeviceArena(1024, debug=True)
    offs = [a.alloc(128) for _ in range(8)]      # slab now full
    with pytest.raises(ArenaError, match="exhausted"):
        a.alloc(1)
    for o in offs[2:5]:                   # free a middle run
        a.free(o)
    # coalesced: one 384B hole serves a 384B request
    o = a.alloc(384)
    assert o == offs[2]
    a.free(o)
    for o in (offs[0], offs[1], offs[5], offs[6], offs[7]):
        a.free(o)
    assert a.bytes_in_use == 0
    assert a._free == [(0, 1024)]         # fully re-coalesced


def test_arena_double_free_raises():
    a = DeviceArena(1024)
    o = a.alloc(128)
    a.free(o)
    with pytest.raises(ArenaError, match="unallocated"):
        a.free(o)
    with pytest.raises(ArenaError, match="unallocated"):
        a.free(999)


def test_eager_driver_free_returns_offsets(rng):
    """The satellite bugfix: HalDriver.free must actually return the
    buffer's range to the arena free-list (it used to only count)."""
    drv = rhal.make_eager_driver(debug_arena=True)
    base = drv.arena.bytes_in_use
    bufs = [drv.alloc((64, 64), "float32") for _ in range(4)]
    assert drv.arena.bytes_in_use == base + 4 * 64 * 64 * 4
    for b in bufs:
        drv.free(b)
    assert drv.arena.bytes_in_use == base        # all ranges returned
    drv.arena.check()                            # invariants hold (debug)


def test_freed_scratch_read_before_free_does_not_leak():
    """Regression: a scratch that is READ and then explicitly FREEd must
    reach the FREE thunk as a real buffer (not reference-dropped at last
    read), so its arena range is returned — repeated executions keep
    bytes_in_use flat instead of leaking one range per run."""
    from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc
    t = {
        "x": TensorDesc("x", (32,), "float32", "input"),
        "s": TensorDesc("s", (32,), "float32", "scratch"),
        "y": TensorDesc("y", (32,), "float32", "output"),
    }
    ops = [RCBOp(Op.ALLOC, ("s",), (), {"shape": [32],
                                        "dtype": "float32"}),
           RCBOp(Op.ADD, ("y",), ("x", "s")),     # s's last read
           RCBOp(Op.FREE, ("s",))]                # then the explicit FREE
    prog = RCBProgram("leak", t, [RCB(0, "layer", (), tuple(ops))])
    drv = rhal.make_eager_driver(debug_arena=True)
    ex = Executor(driver=drv)
    x = np.ones(32, np.float32)
    base = drv.arena.bytes_in_use
    bound = rbl.bind(prog, inputs={"x": x})
    for _ in range(5):
        out = ex.run(bound, inputs={"x": x})
        assert "y" in out
        assert drv.arena.bytes_in_use == base     # linked: no leak
    for _ in range(5):
        ex.run_interpreted(bound, inputs={"x": x})
        assert drv.arena.bytes_in_use == base     # interpreted: no leak


def test_blocking_driver_plan_advertises_no_overlap(rng):
    """A driver without async DMA slots executes everything blocking —
    its LinkedProgram's plan must not report split-phase bytes."""
    import dataclasses
    drv = rhal.make_eager_driver()
    drv = dataclasses.replace(drv, dma_async=None, dma_wait=None,
                              dma_async_batch=None)
    K, n = 2, 8
    prog = rctc.compile_dma_pipeline(K, n)
    fs = rimfs.mount(rimfs.pack({"b": rng.randn(n, n)
                                 .astype(np.float32)}))
    ins = {f"in{i}": rng.randn(n, n).astype(np.float32) for i in range(K)}
    linked = linker.link(rbl.bind(prog, rimfs=fs, inputs=ins), drv)
    assert linked.residency.bytes_overlapped == 0
    assert linked.residency.prefetch_syms == ()
    assert linked.prologue == () and linked.epilogue == ()
    assert linked.residency.bytes_moved == 2 * K * n * n * 4


def test_alloc_free_ops_roundtrip_through_arena():
    """Explicit ALLOC/FREE RCB ops drive the arena through the vtable."""
    from repro.core.rcb import Op, RCB, RCBOp, RCBProgram, TensorDesc
    t = {
        "x": TensorDesc("x", (4,), "float32", "input"),
        "s": TensorDesc("s", (32, 32), "float32", "scratch"),
        "y": TensorDesc("y", (4,), "float32", "output"),
    }
    ops = [RCBOp(Op.ALLOC, ("s",), (), {"shape": [32, 32],
                                        "dtype": "float32"}),
           RCBOp(Op.FREE, ("s",)),
           RCBOp(Op.PASSTHROUGH, ("y",), ("x",))]
    prog = RCBProgram("af", t, [RCB(0, "layer", (), tuple(ops))])
    drv = rhal.make_eager_driver(debug_arena=True)
    ex = Executor(driver=drv)
    base = drv.arena.bytes_in_use
    out = ex.run(rbl.bind(prog, inputs={"x": np.ones(4, np.float32)}))
    assert "y" in out
    assert drv.arena.bytes_in_use == base        # ALLOC's range was freed


# ---------------------------------------------------------------------------
# Split-phase DMA ticket protocol
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("direction", ["h2d", "d2h"])
def test_dma_ticket_double_wait_raises_eager(direction):
    """Regression (satellite fix): a DmaTicket could be redeemed twice
    silently — on a raw-pointer backend the descriptor is recycled at
    wait, so the second wait would observe another transfer's state."""
    drv = rhal.make_eager_driver()
    host = np.ones(32, np.float32)
    buf = host if direction == "h2d" \
        else drv.wait_dma(drv.initiate_dma(host, "h2d"))
    t = drv.dma_async(buf, direction)
    drv.dma_wait(t)
    with pytest.raises(rhal.DmaError, match="redeemed"):
        drv.dma_wait(t)


def test_dma_ticket_double_wait_raises_trace():
    drv = rhal.make_trace_driver()
    t = drv.dma_async(np.ones(8, np.float32), "h2d")
    drv.dma_wait(t)
    with pytest.raises(rhal.DmaError, match="redeemed"):
        drv.dma_wait(t)


def test_dma_batch_tickets_each_redeem_once():
    drv = rhal.make_eager_driver()
    hosts = [np.full(16, i, np.float32) for i in range(3)]
    tickets = drv.dma_async_batch(hosts, "h2d")
    for t in tickets:
        drv.dma_wait(t)
    for t in tickets:
        with pytest.raises(rhal.DmaError, match="redeemed"):
            drv.dma_wait(t)


# ---------------------------------------------------------------------------
# Residency plan
# ---------------------------------------------------------------------------

def _aligned(n):
    return (n + 127) // 128 * 128


def test_plan_dma_pipeline_schedule(rng):
    K, n = 4, 16
    prog = rctc.compile_dma_pipeline(K, n)
    fs = rimfs.mount(rimfs.pack({"b": rng.randn(n, n)
                                 .astype(np.float32)}))
    ins = {f"in{i}": rng.randn(n, n).astype(np.float32) for i in range(K)}
    bound = rbl.bind(prog, rimfs=fs, inputs=ins)
    plan = linker.plan_residency(bound)
    # every H2D is prefetchable (sources live at entry), every D2H drains
    assert len(plan.prefetch_syms) == K
    assert len(plan.drain_syms) == K
    assert plan.bytes_moved == 2 * K * n * n * 4       # K h2d + K d2h
    assert plan.bytes_overlapped == plan.bytes_moved   # 100% split-phase
    # steady-state residency: weight + one dev + one acc buffer
    blk = _aligned(n * n * 4)
    assert plan.high_water == 3 * blk
    # dead dev/acc ranges are donated to later stages
    assert len(plan.donated) >= 1
    # offsets aligned and pairwise disjoint while simultaneously live is
    # guaranteed by the arena; spot-check alignment here
    assert all(o % 128 == 0 for o in plan.offsets.values())


def test_plan_high_water_matches_arena_replay(rng):
    """Replaying the plan's event schedule on a fresh arena reproduces the
    precomputed high-water mark exactly (the plan IS an arena trace)."""
    cfg = __import__("repro.configs.resnet18",
                     fromlist=["CONFIG"]).CONFIG.smoke()
    from repro.models import resnet as rn
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    prog, image = rctc.compile_resnet18(cfg, rn.fold_bn(params), batch=1)
    bound = rbl.bind(prog, rimfs=rimfs.mount(image))
    plan = linker.plan_residency(bound)
    assert plan.high_water > 0
    # replay: identical walk, fresh arena -> identical peak
    replay = linker.plan_residency(bound)
    assert replay.high_water == plan.high_water
    assert replay.offsets == plan.offsets


def test_linked_pipeline_outputs_bit_identical(rng):
    K, n = 3, 8
    prog = rctc.compile_dma_pipeline(K, n)
    fs = rimfs.mount(rimfs.pack({"b": rng.randn(n, n)
                                 .astype(np.float32)}))
    ins = {f"in{i}": rng.randn(n, n).astype(np.float32) for i in range(K)}
    ex = Executor()
    o_i = ex.run_interpreted(rbl.bind(prog, rimfs=fs, inputs=dict(ins)))
    o_l = ex.run(rbl.bind(prog, rimfs=fs, inputs=dict(ins)))
    for k in o_i:
        np.testing.assert_array_equal(
            np.asarray(o_i[k]),
            np.asarray(jax.block_until_ready(o_l[k])))


# ---------------------------------------------------------------------------
# Property tests (hypothesis optional, like PR 1)
# ---------------------------------------------------------------------------

if _HAS_HYPOTHESIS:
    @given(st.lists(
        st.tuples(st.booleans(), st.integers(1, 4096)),
        min_size=1, max_size=60))
    @settings(max_examples=60, deadline=None)
    def test_property_arena_no_overlap_aligned(events):
        """Random alloc/free sequences: live ranges never overlap, every
        offset stays 128 B-aligned, and usage accounting balances."""
        a = DeviceArena(1 << 20, debug=True)   # debug: invariants per op
        live: list[int] = []
        expect_in_use = 0
        peak = 0
        for is_alloc, size in events:
            if is_alloc or not live:
                try:
                    off = a.alloc(size)
                except ArenaError:
                    continue
                assert off % 128 == 0
                live.append(off)
                expect_in_use += _aligned(size)
            else:
                off = live.pop(size % len(live))
                expect_in_use -= a._live[off]
                a.free(off)
            peak = max(peak, expect_in_use)
            # no two live ranges overlap (debug check() also asserts this)
            ranges = a.live_ranges()
            for (o1, s1), (o2, s2) in zip(ranges, ranges[1:]):
                assert o1 + s1 <= o2
        assert a.bytes_in_use == expect_in_use
        assert a.high_water == peak            # matches replayed peak

    @given(st.integers(1, 6), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_property_plan_peak_matches_closed_form(stages, scale):
        """For the stage pipeline the precomputed high-water mark equals
        the closed-form steady-state residency: weight + dev + acc."""
        n = 8 * scale
        prog = rctc.compile_dma_pipeline(stages, n)
        rng = np.random.RandomState(0)
        fs = rimfs.mount(rimfs.pack({"b": rng.randn(n, n)
                                     .astype(np.float32)}))
        ins = {f"in{i}": rng.randn(n, n).astype(np.float32)
               for i in range(stages)}
        plan = linker.plan_residency(rbl.bind(prog, rimfs=fs, inputs=ins))
        # steady state: weight + one dev + one acc block, regardless of
        # stage count — dead stage-k ranges are donated to stage k+1
        assert plan.high_water == 3 * _aligned(n * n * 4)
