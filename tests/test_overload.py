"""Brown-out overload control plane (ISSUE 10): degradation-ladder walk
with hysteresis, typed shed verdicts on the wire, terminal (infeasible)
verdicts that never burn retries, client retry-after hints, LM decode
clamping, and the tile-group circuit breaker."""
import threading
import time

import numpy as np
import pytest

from repro.core import rctc, rhal, rimfs
from repro.serving.overload import (MAX_RUNG, BrownoutController,
                                    OverloadConfig)
from repro.serving.server import (Client, InferenceServer, RequestShed,
                                  ServerBusy, _Work)

DEPTH, N = 6, 16


@pytest.fixture(scope="module")
def chain_setup():
    prog = rctc.compile_gemm_chain(DEPTH, N)
    files = rctc.gemm_chain_weights(DEPTH, N)
    return prog, files, rimfs.pack(files)


def _start(prog, image, mesh_groups=0, **kw):
    mesh = rhal.TileMesh(mesh_groups) if mesh_groups else None
    server = InferenceServer(mesh=mesh, **kw)
    addr = server.start()
    client = Client(addr)
    client.provision(image, prog.encode())
    return server, addr, client


def _x(seed=0):
    return np.random.RandomState(seed).randn(N, N).astype(np.float32)


def _heat(server, n, seconds=0.4):
    """Feed the dispatcher's queue-wait telemetry over-threshold samples
    (the ladder's pressure signal), deterministically."""
    for _ in range(n):
        server._loop.queue_wait.record_latency(seconds)


def _wedge_dispatcher(server):
    gate = threading.Event()
    entered = threading.Event()

    def ctl():
        entered.set()
        gate.wait(30)

    # the bounded dispatch queue may still be draining a previous burst;
    # retry the control submit until a slot frees instead of asserting
    # on a racy snapshot
    deadline = time.time() + 5
    while not server._loop.submit(
            _Work(frame=None, route=None, control=ctl)):
        assert time.time() < deadline, "dispatch queue never drained"
        time.sleep(0.01)
    assert entered.wait(5)
    return gate


# ----------------------------------------------------------------- ladder
def test_ladder_walks_down_and_back_with_hysteresis(chain_setup):
    """Hot queue-wait p99 ticks descend one rung per escalate_ticks; cool
    ticks climb back one rung per recover_ticks. Each rung's service
    changes (batch window, LM clamp, priority ceiling) apply and revert
    together, and a single noisy tick never moves the ladder."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image)
    try:
        saved_window = server.batch_window
        cfg = OverloadConfig(p99_high=0.1, min_window=2, escalate_ticks=2,
                             recover_ticks=2, max_new_clamp=4,
                             shed_priority=2)
        over = BrownoutController(server, cfg)
        rungs = []
        for _ in range(2 * MAX_RUNG):
            _heat(server, cfg.min_window, 0.4)
            over.tick()
            rungs.append(over.rung)
        assert rungs[0] == 0 and rungs[1] == 1   # hysteresis held tick 1
        assert over.rung == MAX_RUNG
        assert server.batch_window == 1
        assert server.max_new_clamp == cfg.max_new_clamp
        assert server.scheduler.priority_ceiling == cfg.shed_priority
        assert over.breaker.state == "closed"    # no failing group: rung 4
        over.tick()                              # trips nothing
        assert over.rung == MAX_RUNG             # one cool tick holds
        for _ in range(2 * MAX_RUNG + 2):
            over.tick()
        assert over.rung == 0
        assert server.batch_window == saved_window
        assert server.max_new_clamp is None
        assert server.scheduler.priority_ceiling is None
        moves = [(p["from"], p["to"]) for k, p in over.events
                 if k == "brownout_rung"]
        assert moves[:4] == [(0, 1), (1, 2), (2, 3), (3, 4)]
        assert moves[-1] == (1, 0)
        assert over.summary()["name"] == "normal"
    finally:
        client.close()
        server.stop()


# ----------------------------------------------------------- typed sheds
def test_rung3_sheds_low_priority_with_typed_verdict(chain_setup):
    """At rung 3, admissions at or past the priority ceiling get an
    honest machine-readable refusal: kind "brownout", retryable, with a
    retry-after hint. Urgent classes keep full bit-identical service,
    and dropping the rung restores the shed class."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image)
    try:
        over = BrownoutController(server, OverloadConfig(shed_priority=2))
        x = _x(1)
        ref = client.infer(input=x)
        over.set_rung(3, reason="test")
        with pytest.raises(RequestShed) as ei:
            client.infer(input=x, priority=5)
        e = ei.value
        assert e.kind == "brownout"
        assert e.retryable is True
        assert e.retry_after_ms >= 1
        out = client.infer(input=x)              # priority 1: still served
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        over.tick()                              # honest accounting
        shed_n = sum(p["n"] for k, p in over.events
                     if k == "brownout_shed")
        assert shed_n == 1
        over.set_rung(0, reason="test")
        out = client.infer(input=x, priority=5)  # capacity returned
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
    finally:
        client.close()
        server.stop()


def test_infeasible_deadline_is_terminal_never_retried(chain_setup):
    """An infeasible deadline is a TERMINAL verdict: re-sending the same
    request cannot help, so a retry-enabled client fails fast without
    burning a single retry."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image)
    try:
        cl = Client(addr, retries=5, backoff=0.01)
        with pytest.raises(RequestShed) as ei:
            cl.infer(input=_x(2), deadline_ms=0.0)
        e = ei.value
        assert e.kind == "infeasible"
        assert e.retryable is False
        assert e.retry_after_ms == 0
        assert cl.retry_stats["retries"] == 0
        cl.close()
    finally:
        client.close()
        server.stop()


def test_client_honors_retry_after_hint(chain_setup):
    """Busy refusals carry a retry_after_ms hint; a retrying client
    sleeps at least that long instead of hammering the same wall, and
    counts every honored hint."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, max_queue=4)
    try:
        x = _x(3)
        ref = client.infer(input=x)

        # the hint is on the wire even for a zero-retry client: burst
        # into the wedge, THEN release it and collect — waiting on an
        # accepted request while the dispatcher is still wedged would
        # deadlock against our own gate
        gate = _wedge_dispatcher(server)
        plain = Client(addr)
        try:
            rids = [plain.infer_async(input=x) for _ in range(10)]
        finally:
            gate.set()
        hints, served = [], 0
        for rid in rids:
            try:
                plain.result(rid)
                served += 1
            except ServerBusy as e:
                assert e.kind == "busy" and e.retryable is True
                hints.append(e.retry_after_ms)
        assert hints and all(h >= 1 for h in hints)
        assert served + len(hints) == 10
        plain.close()

        # retrying clients honor it: a concurrent burst into the wedge
        # fully succeeds, and the hinted counter moves with EVERY busy
        # retry (the server always sends a hint with a busy refusal)
        gate = _wedge_dispatcher(server)
        results, errors, stats = [], [], []
        lock = threading.Lock()

        def worker(cid):
            cl = Client(addr, retries=20, backoff=0.01, retry_seed=cid)
            try:
                for _ in range(6):
                    out = cl.infer(input=x)
                    with lock:
                        results.append(out)
                with lock:
                    stats.append(dict(cl.retry_stats))
            except Exception as e:          # pragma: no cover
                errors.append(e)
            finally:
                cl.close()

        threads = [threading.Thread(target=worker, args=(c,))
                   for c in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.15)                    # let the burst hit the wedge
        gate.set()
        for t in threads:
            t.join(timeout=60)
        assert not errors and len(results) == 24
        for out in results:
            for k in ref:
                np.testing.assert_array_equal(ref[k], out[k])
        assert sum(s["busy"] for s in stats) > 0, \
            "burst never saw backpressure — wedge did not engage"
        for s in stats:
            assert s["hinted"] == s["busy"]
    finally:
        client.close()
        server.stop()


# ---------------------------------------------------------------- LM path
def _lm_server(rng, **over_kw):
    import jax

    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.models.common import init_params
    from repro.serving.engine import ServingEngine

    cfg = get_config("qwen2-1.5b-smoke")
    params = init_params(jax.random.PRNGKey(0), tf.model_specs(cfg))
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    server = InferenceServer(engine=eng)
    addr = server.start()
    client = Client(addr)
    over = BrownoutController(server, OverloadConfig(**over_kw))
    prompt = rng.randint(0, cfg.vocab_size, (6,)).astype(np.int32)
    return server, client, over, prompt


def test_rung2_clamps_lm_decode_budget(rng):
    """At rung 2 LM admissions get max_new clamped: the same request
    yields a greedy PREFIX of the full answer — degraded honestly, never
    differently. Recovery restores the full budget."""
    server, client, over, prompt = _lm_server(rng, max_new_clamp=2)
    try:
        full = list(client.infer(prompt=prompt, max_new=6)["tokens"])
        short = list(client.infer(prompt=prompt, max_new=2)["tokens"])
        assert len(short) < len(full)
        over.set_rung(2, reason="test")
        clamped = list(client.infer(prompt=prompt, max_new=6)["tokens"])
        # clamped max_new=6 behaves EXACTLY like asking for max_new=2:
        # a greedy prefix of the full answer, never a different answer
        assert clamped == short == full[:len(short)]
        over.set_rung(0, reason="test")
        again = list(client.infer(prompt=prompt, max_new=6)["tokens"])
        assert again == full
    finally:
        client.close()
        server.stop()


def test_lm_brownout_shed_is_typed_and_idempotent_retryable(rng):
    """The engine path sheds with the same typed verdicts; a request
    refused at admission sampled zero tokens, so the verdict is
    retryable — the idempotency guard only blocks mid-sampling sheds."""
    server, client, over, prompt = _lm_server(rng, shed_priority=2)
    try:
        ref = list(client.infer(prompt=prompt, max_new=3)["tokens"])
        over.set_rung(3, reason="test")
        with pytest.raises(RequestShed) as ei:
            client.infer(prompt=prompt, max_new=3, priority=5)
        e = ei.value
        assert e.kind == "brownout"
        assert e.retryable is True              # zero tokens sampled
        assert e.retry_after_ms >= 1
        out = list(client.infer(prompt=prompt, max_new=3)["tokens"])
        assert out == ref                       # urgent class: full service
        over.set_rung(0, reason="test")
    finally:
        client.close()
        server.stop()


# -------------------------------------------------------- circuit breaker
def test_circuit_breaker_trips_probes_and_closes(chain_setup):
    """Rung 4 circuit-breaks the worst FAILING tile group: the kill rides
    the existing quarantine path (failover keeps serving bit-identical),
    the half-open probe golden-checks the revived group against the
    survivors' answer, and only a bit-identical probe closes the
    circuit."""
    prog, files, image = chain_setup
    server, addr, client = _start(prog, image, mesh_groups=2)
    try:
        over = BrownoutController(server, OverloadConfig(
            breaker_cooldown_ticks=1, recover_ticks=100))
        x = _x(5)
        ref = client.infer(input=x)
        mesh = server.mesh
        # this group failed twice on the record (tile_failure events)
        server.platform.post("tile_failure", {"group": 1})
        server.platform.post("tile_failure", {"group": 1})
        rep = over.set_rung(4, reason="test")
        assert rep["tripped"] == 1
        assert over.breaker.state == "open"
        assert not mesh.alive(1)
        out = client.infer(input=x)        # quarantined: failover serves
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        over.tick()                        # cooldown expires: golden probe
        assert over.breaker.state == "closed"
        assert mesh.alive(1)
        kinds = [k for k, _ in over.events]
        assert "circuit_open" in kinds and "circuit_closed" in kinds
        assert over.breaker.stats == {"trips": 1, "probes": 1, "closes": 1}
        out = client.infer(input=x)        # full mesh back in rotation
        for k in ref:
            np.testing.assert_array_equal(ref[k], out[k])
        over.set_rung(0, reason="test")
    finally:
        client.close()
        server.stop()
