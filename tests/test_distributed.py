"""Multi-device semantics (compression, pipeline, dp step) — these spawn a
subprocess with 8 forced host devices so the main test process keeps its
single-device view."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _run(script: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=REPO_SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_compressed_psum_matches_exact():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.collectives import compressed_psum, \
        shard_map_compat
    mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
    g = jnp.asarray(np.random.RandomState(0).randn(8, 64), jnp.float32)
    exact = jnp.mean(g, axis=0)
    for method, tol in [("none", 1e-6), ("bf16", 2e-2), ("int8_ef", 3e-2)]:
        @functools.partial(shard_map_compat, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"))
        def red(x, method=method):
            r, _ = compressed_psum(x[0], "data", method)
            return r[None]
        out = red(g)[0]
        err = float(jnp.max(jnp.abs(out - exact)))
        assert err < tol, (method, err)
    print("ok")
    """)


def test_int8_error_feedback_converges():
    """With error feedback, the mean of repeated compressed reductions of a
    CONSTANT gradient converges to the true mean (bias -> 0)."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed.collectives import compressed_psum, \
        shard_map_compat
    mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
    g = jnp.asarray(np.random.RandomState(1).randn(8, 32), jnp.float32)
    exact = jnp.mean(g, axis=0)
    @functools.partial(shard_map_compat, mesh=mesh,
                       in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")))
    def red(x, e):
        r, ne = compressed_psum(x[0], "data", "int8_ef", e[0])
        return r[None], ne[None]
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(exact)
    n = 12
    for _ in range(n):
        r, err = red(g, err)
        acc = acc + r[0]
    bias = float(jnp.max(jnp.abs(acc / n - exact)))
    one = float(jnp.max(jnp.abs(red(g, jnp.zeros_like(g))[0][0] - exact)))
    assert bias < one * 0.6, (bias, one)   # feedback beats one-shot
    print("ok")
    """)


def test_pipeline_matches_stacked_forward():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.distributed.pipeline import pipeline_forward
    mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("stage",))
    rng = np.random.RandomState(0)
    ws = jnp.asarray(rng.randn(4, 16, 16) * 0.3, jnp.float32)
    mbs = jnp.asarray(rng.randn(6, 8, 16), jnp.float32)
    def stage_fn(w, x):
        return jnp.tanh(x @ w)
    run = pipeline_forward(stage_fn, mesh)
    out = run(ws, mbs)
    ref = mbs
    for i in range(4):
        ref = jnp.tanh(ref @ ws[i])
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err
    print("ok")
    """)


def test_production_rules_compile_small_model():
    """The RBL rule engine drives a real pjit end-to-end on an 8-device
    (2 data x 4 model) mesh: lower, compile AND execute a train step."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.distributed.sharding import axis_rules
    from repro.launch.steps import make_train_step, input_specs
    from repro.models import transformer as tf
    from repro.models.common import init_params, shape_structs
    from repro.optim.adamw import adamw_init_specs
    import dataclasses
    cfg = get_config("qwen2-1.5b-smoke")
    cfg = dataclasses.replace(cfg, d_model=128, d_ff=256, num_heads=8,
                              num_kv_heads=4, head_dim=16, vocab_size=512)
    mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    with axis_rules(mesh, "train"):
        specs = tf.model_specs(cfg)
        params = init_params(jax.random.PRNGKey(0), specs)
        opt = init_params(jax.random.PRNGKey(1),
                          adamw_init_specs(specs))
        params = jax.device_put(params, jax.tree.map(
            lambda s: s.sharding, shape_structs(specs)))
        step = make_train_step(cfg)
        rng = np.random.RandomState(0)
        batch = {"inputs": jnp.asarray(rng.randint(0, 512, (4, 32))),
                 "targets": jnp.asarray(rng.randint(0, 512, (4, 32)))}
        with mesh:
            p2, o2, m = jax.jit(step)(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
    print("ok")
    """)
