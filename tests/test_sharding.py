"""RBL sharding resolution: the shape-aware logical->physical rule engine."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                       # optional test dependency
    _HAS_HYPOTHESIS = False

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed.sharding import RULE_SETS, logical_to_pspec

def _abstract_mesh(sizes, names):
    try:                       # jax >= 0.5: AbstractMesh(sizes, names)
        return AbstractMesh(sizes, names)
    except TypeError:          # jax 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


MESH1 = _abstract_mesh((16, 16), ("data", "model"))
MESH2 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_train_batch_uses_pod_and_data():
    spec = logical_to_pspec((256, 4096), ("batch", None),
                            RULE_SETS["train"], MESH2)
    assert spec == P(("pod", "data"))


def test_single_pod_falls_back_to_data():
    spec = logical_to_pspec((256, 4096), ("batch", None),
                            RULE_SETS["train"], MESH1)
    assert spec == P("data")


def test_indivisible_heads_replicate():
    # qwen3: 40 heads % 16 != 0 -> replicated, seq takes model instead
    spec = logical_to_pspec((16, 4096, 40, 128),
                            ("batch", "seq", "heads", None),
                            RULE_SETS["train"], MESH1)
    assert spec == P("data", "model")        # heads entry dropped


def test_positional_priority_seq_before_heads():
    # dims resolve left->right: seq grabs "model" first; heads (32, also
    # divisible) then finds model used -> replicated. Both layouts keep the
    # causal softmax collective-free; positional priority keeps resolution
    # deterministic.
    spec = logical_to_pspec((16, 4096, 32, 128),
                            ("batch", "seq", "heads", None),
                            RULE_SETS["train"], MESH1)
    assert spec == P("data", "model")


def test_vocab_32001_replicates():
    # hymba vocab 32001 % 16 != 0 -> vocab dim replicated; "embed" has no
    # rule in the train set -> the table ends up fully replicated (correct:
    # a 98 MB table is cheap; correctness over cleverness)
    spec = logical_to_pspec((32001, 1600), ("vocab", "embed"),
                            RULE_SETS["train"], MESH1)
    assert spec == P()


def test_batch1_decode_seq_grabs_data_model():
    spec = logical_to_pspec((40, 1, 524288, 8, 128),
                            ("layers", "batch", "seq", "kv_heads", None),
                            RULE_SETS["decode"], MESH1)
    assert spec == P(None, None, ("data", "model"))


def test_decode_batch_and_seq():
    spec = logical_to_pspec((40, 128, 32768, 8, 128),
                            ("layers", "batch", "seq", "kv_heads", None),
                            RULE_SETS["decode"], MESH2)
    # batch -> (pod,data); seq -> ("data","model") blocked (data used) ->
    # "model"; kv_heads 8 % 16 -> replicated
    assert spec == P(None, ("pod", "data"), "model")


_LOGICAL = ["batch", "seq", "embed", "heads", "kv_heads", "mlp", "experts",
            "vocab", "fsdp", "state", "layers", None]


if _HAS_HYPOTHESIS:
    @given(st.lists(st.tuples(st.sampled_from(_LOGICAL),
                              st.integers(1, 4096)), min_size=1, max_size=5),
           st.sampled_from(["train", "prefill", "decode"]))
    @settings(max_examples=200, deadline=None)
    def test_property_resolver_invariants(dims, mode):
        """For ANY shape/axes combination: every mesh axis is used at most once
        and every sharded dim is divisible by its mesh-axes product."""
        axes = tuple(a for a, _ in dims)
        shape = tuple(s for _, s in dims)
        spec = logical_to_pspec(shape, axes, RULE_SETS[mode], MESH2)
        sizes = {"pod": 2, "data": 16, "model": 16}
        used = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
            if entry is None:
                continue
            group = (entry,) if isinstance(entry, str) else tuple(entry)
            used.extend(group)
            total = int(np.prod([sizes[a] for a in group]))
            assert dim % total == 0
        assert len(used) == len(set(used))


def test_shard_noop_outside_context():
    import jax.numpy as jnp
    from repro.distributed.sharding import shard
    x = jnp.ones((4, 4))
    np.testing.assert_array_equal(shard(x, "batch", None), x)
