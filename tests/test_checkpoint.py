"""Checkpoint/restart: RIMFS images, CRC fallback, async save."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager, load_checkpoint, \
    save_checkpoint


def _state(seed):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"m": jnp.ones((16, 16)) * 0.5},
            "step": jnp.asarray(seed, jnp.int32)}


def test_save_load_roundtrip(tmp_path):
    state = _state(7)
    save_checkpoint(tmp_path / "c.rimfs", state, step=7, extra={"lr": 0.1})
    back, step, extra = load_checkpoint(tmp_path / "c.rimfs", state)
    assert step == 7 and extra == {"lr": 0.1}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(_state(s), step=s)
    assert mgr.all_steps() == [2, 3]
    back, step, _ = mgr.restore_latest(_state(0))
    assert step == 3


def test_corrupt_checkpoint_falls_back(tmp_path):
    """Torn write on the newest checkpoint -> restart uses the previous one
    (the node-failure recovery path)."""
    mgr = CheckpointManager(tmp_path, keep=3, async_save=False)
    mgr.save(_state(1), step=1)
    mgr.save(_state(2), step=2)
    newest = sorted(tmp_path.glob("ckpt_*.rimfs"))[-1]
    raw = bytearray(newest.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    newest.write_bytes(bytes(raw))
    back, step, _ = mgr.restore_latest(_state(0))
    assert step == 1                      # fell back past the corrupt one


def test_async_save_snapshot_isolated(tmp_path):
    """Async save must snapshot values BEFORE the caller mutates state."""
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    state = _state(5)
    mgr.save(state, step=5)
    # mutate immediately (simulating the next donated train step)
    state["params"]["w"] = state["params"]["w"] * 0.0
    mgr.wait()
    back, step, _ = mgr.restore_latest(_state(0))
    assert step == 5
    assert float(np.abs(np.asarray(back["params"]["w"])).sum()) > 0


def test_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.restore_latest(_state(0)) is None
