"""ResNet-18 case study through the full AEG path (paper §3.3/§4.3)."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.resnet18 import CONFIG
from repro.core import quant, rbl, rctc, rimfs
from repro.core.executor import Executor
from repro.core.rcb import RCBProgram
from repro.models import resnet as rn


def _setup(rng, batch=4):
    cfg = CONFIG.smoke()
    params = rn.init_resnet(jax.random.PRNGKey(0), cfg)
    x = rng.rand(batch, cfg.image_size, cfg.image_size, 3) \
        .astype(np.float32)
    return cfg, params, x


def test_rcb_resnet_matches_oracle(rng):
    cfg, params, x = _setup(rng)
    folded = rn.fold_bn(params)
    prog, image = rctc.compile_resnet18(cfg, folded, batch=x.shape[0])
    prog = RCBProgram.decode(prog.encode())           # over the wire
    bound = rbl.bind(prog, rimfs=rimfs.mount(image), inputs={"input": x},
                     verify_weights=True)
    out = np.asarray(Executor().run(bound)["output"])
    ref = np.asarray(rn.resnet_forward(cfg, params, jnp.asarray(x)))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_int8_resnet_agreement(rng):
    """INT8 deployment mechanism check. With an UNTRAINED net the logits are
    near-ties, so argmax agreement is a noisy metric (the paper's 0.22pt
    top-1 gap is on trained ImageNet weights); we require argmax agreement
    well above chance AND small probability drift."""
    cfg, params, x = _setup(rng, batch=32)
    folded = rn.fold_bn(params)
    pack = quant.quantize_resnet(cfg, folded, x[:4])
    prog_q, image_q = rctc.compile_resnet18(cfg, folded, batch=32,
                                            int8=pack)
    bound = rbl.bind(prog_q, rimfs=rimfs.mount(image_q),
                     inputs={"input": x})
    out_q = np.asarray(Executor().run(bound)["output"])
    ref = np.asarray(rn.resnet_forward(cfg, params, jnp.asarray(x)))
    assert bool(np.all(np.isfinite(out_q)))
    agree = quant.top1_agreement(ref, out_q)
    assert agree >= 0.6, agree                  # chance = 1/num_classes
    assert float(np.mean(np.abs(ref - out_q))) < 0.08


def test_fused_resnet_single_dispatch(rng):
    """Fused mode executes the whole network as ONE XLA program."""
    cfg, params, x = _setup(rng)
    folded = rn.fold_bn(params)
    prog, image = rctc.compile_resnet18(cfg, folded, batch=x.shape[0])
    bound = rbl.bind(prog, rimfs=rimfs.mount(image))
    ex = Executor()
    fused = ex.fuse(bound)
    out = np.asarray(fused({"input": x}, ex.weights_from(bound))["output"])
    ref = np.asarray(rn.resnet_forward(cfg, params, jnp.asarray(x)))
    np.testing.assert_allclose(out, ref, atol=1e-5, rtol=1e-5)


def test_weights_image_size_tracks_params(rng):
    """Paper: 12.63 MB parameter buffer — our image overhead must be <1%."""
    cfg, params, x = _setup(rng)
    folded = rn.fold_bn(params)
    _, image = rctc.compile_resnet18(cfg, folded, batch=1)
    payload = sum(np.asarray(v).nbytes for v in folded.values())
    assert len(image) < payload * 1.02 + 4096
