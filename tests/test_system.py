"""End-to-end behaviour of the paper's system: the full Provision -> Bind ->
Dispatch -> Sync cycle, eager-vs-fused latency determinism, and the
block-size overhead regime (qualitative versions of Tables 1 and 3)."""
import time

import numpy as np

from repro.core import rbl, rctc, rimfs
from repro.core.executor import Executor
from repro.core.rtpm import Platform


def test_four_phase_execution_flow(rng):
    """Provision (RIMFS+RCBs) -> Bind (RBL) -> Dispatch (RHAL) -> Sync."""
    prog = rctc.compile_conv_relu_softmax()
    w = rng.randn(3, 3, 3, 9).astype(np.float32)
    plat = Platform()
    plat.provision(image=rimfs.pack({"w_conv": w}),
                   program_bytes=prog.encode())
    x = rng.randn(1, 8, 8, 3).astype(np.float32)
    bound = plat.bind(inputs={"input": x})
    ex = Executor(rtpm=plat)
    out = ex.run(bound)
    plat.events.process()
    assert out["output"].shape == (1, 9)
    assert np.isclose(float(np.sum(out["output"])), 1.0, atol=1e-5)


def test_fused_mean_latency_below_eager(rng):
    """Paper Table 3 mechanism: the single-dispatch path is faster than the
    op-at-a-time path on the same RCB program. Sampled steady-state (GC
    parked, median) per the benchmark methodology so a collection pause
    elsewhere in the suite cannot flip a microsecond-scale comparison."""
    import gc

    prog = rctc.compile_matmul(64)
    a = rng.randn(64, 64).astype(np.float32)
    b = rng.randn(64, 64).astype(np.float32)
    fs = rimfs.mount(rimfs.pack({"b": b}))
    ex = Executor()

    bound = rbl.bind(prog, rimfs=fs, inputs={"a": a})
    bound2 = rbl.bind(prog, rimfs=fs)
    fused = ex.fuse(bound2)
    w = ex.weights_from(bound2)
    fused({"a": a}, w)["output"].block_until_ready()    # compile

    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        eager_lat = []
        for _ in range(60):
            t0 = time.perf_counter()
            ex.run(bound)
            eager_lat.append(time.perf_counter() - t0)
        fused_lat = []
        for _ in range(60):
            t0 = time.perf_counter()
            fused({"a": a}, w)["output"].block_until_ready()
            fused_lat.append(time.perf_counter() - t0)
    finally:
        if was_enabled:
            gc.enable()

    e_mu = float(np.median(eager_lat[10:]))
    f_mu = float(np.median(fused_lat[10:]))
    assert f_mu < e_mu, (e_mu, f_mu)


def test_per_transfer_overhead_shrinks_with_block_size(rng):
    """Paper Table 1 regime: per-byte cost of many small PASSTHROUGH
    transfers exceeds that of few large ones (fixed per-op cost)."""
    total = 1 << 20                                  # 1 MB total

    def per_byte_cost(block):
        n = total // block
        prog = rctc.compile_passthrough((block,))
        bound = rbl.bind(prog, inputs={})
        ex = Executor()
        x = rng.randn(block).astype(np.float32)
        t0 = time.perf_counter()
        for _ in range(n):
            ex.run(bound, inputs={"input": x})
        return (time.perf_counter() - t0) / total

    small = per_byte_cost(256)
    large = per_byte_cost(64 * 1024)
    assert small > 2.0 * large, (small, large)
